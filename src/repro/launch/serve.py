"""DISLAND serving driver (the paper's end-to-end application).

Builds the full index over a synthetic road graph, uploads the device
tensors, then serves batched shortest-distance queries — by default
through the case-bucketing QueryPlanner (each jitted sub-program does
only its bucket's work), or monolithically (--mode fused) or sharded
over a device mesh (--mode sharded) — and validates a sample against
host Dijkstra.  Each run appends a perf record to BENCH_serve.json so
the µs/query trajectory is tracked across PRs.

``--update-batches`` turns on the live-traffic loop (planner mode):
between serving batches, a localized weight-update batch is absorbed by
the incremental refresh path and published as a new index epoch
(DESIGN.md §9); refresh latency, the from-scratch rebuild baseline, and
an exact-match check against that rebuild are all recorded.

    PYTHONPATH=src python -m repro.launch.serve --nodes 4000 \
        --batches 5 --batch-size 1024 --validate 64 \
        --update-batches 3 --update-frac 0.02
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dijkstra
from ..core.device_engine import build_device_index, serve_step
from ..core.dist_engine import EpochedEngine, serve_sharded
from ..core.graph import road_like, traffic_updates
from ..core.paths import path_weight
from ..core.supergraph import build_index, reweight_index
from ..perflog import append_records, latest
from ..runtime import StragglerMonitor
from .mesh import make_host_mesh

REFRESHED_FIELDS = ("frag_apsp", "frag_next", "brow", "d_super",
                    "super_next", "piece_flat", "piece_next",
                    "dist_to_agent")


def _update_loop(engine: EpochedEngine, args, build_s: float) -> list:
    """Absorb --update-batches rounds of localized traffic, serving and
    validating on each new epoch; returns perf records."""
    records = []
    rng = np.random.default_rng(args.seed + 2)
    for r in range(args.update_batches):
        u, v, w = traffic_updates(engine.g, args.update_frac,
                                  seed=args.seed + 10 + r)
        t0 = time.perf_counter()
        stats = engine.apply_updates(u, v, w)
        refresh_s = time.perf_counter() - t0
        s = rng.integers(0, engine.g.n, args.batch_size)
        t = rng.integers(0, engine.g.n, args.batch_size)
        t0 = time.perf_counter()
        out = engine.query(s, t)
        serve_s = time.perf_counter() - t0
        bad = 0
        for i in range(min(args.validate, len(s))):
            want = dijkstra.pair(engine.g, int(s[i]), int(t[i]))
            if not (np.isinf(want) and np.isinf(out[i])) \
                    and abs(out[i] - want) > 1e-4 * max(want, 1):
                bad += 1
        # Two from-scratch baselines on the updated graph, re-measured
        # each round so refresh and baseline share contention
        # conditions:
        #  * full pipeline (build_index + device build) — what a weight
        #    change costs WITHOUT the delta path, since the hybrid
        #    covers are weight-dependent (DESIGN.md §9);
        #  * reweight + device rebuild (same structure) — itself only
        #    possible because overlay weights are derived; also the
        #    array-parity exactness reference (checked on round 0).
        t0 = time.perf_counter()
        build_device_index(build_index(engine.g))
        pipeline_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        sdix = build_device_index(reweight_index(engine.ix, engine.g))
        reweight_s = time.perf_counter() - t0
        scratch_match = all(
            np.array_equal(np.asarray(getattr(engine.dix, f)),
                           np.asarray(getattr(sdix, f)))
            for f in REFRESHED_FIELDS)
        rec = {
            "section": "refresh",
            "graph": f"road{args.nodes}",
            "backend": jax.default_backend(),
            "epoch": engine.epoch,
            "update_frac": args.update_frac,
            "refresh_s": round(refresh_s, 4),
            "scratch_pipeline_s": round(pipeline_s, 4),
            "scratch_reweight_s": round(reweight_s, 4),
            "refresh_over_scratch": round(refresh_s / pipeline_s, 4),
            "refresh_over_reweight": round(refresh_s / reweight_s, 4),
            "initial_build_s": round(build_s, 4),
            "post_refresh_mismatches": bad,
            "scratch_match": scratch_match,
            "serve_batch_ms": round(serve_s * 1e3, 3),
            **stats.as_record(),
        }
        records.append(rec)
        print(f"epoch {engine.epoch}: refresh {refresh_s*1e3:.0f}ms "
              f"({stats.as_record()['dirty_frags']} frags, "
              f"{stats.as_record()['dirty_pieces']} pieces, "
              f"decrease_only={stats.decrease_only}) -> "
              f"{refresh_s / pipeline_s:.1%} of full pipeline "
              f"({pipeline_s:.2f}s), "
              f"{refresh_s / reweight_s:.1%} of reweight rebuild "
              f"({reweight_s:.2f}s), match={scratch_match}; "
              f"validation {bad}/{args.validate} bad")
        assert bad == 0
    return records


def _paths_loop(engine: EpochedEngine, args) -> list:
    """Serve the path-unwinding workload (planner witness programs +
    host-side unwind) and validate a sample; returns perf records."""
    rng = np.random.default_rng(args.seed + 3)
    monitor = StragglerMonitor()
    total = 0
    last = None
    for _ in range(args.batches):
        s = rng.integers(0, engine.g.n, args.batch_size).astype(np.int32)
        t = rng.integers(0, engine.g.n, args.batch_size).astype(np.int32)
        monitor.start()
        dist, paths = engine.query_path(s, t)
        monitor.stop()
        total += args.batch_size
        last = (s, t, dist, paths)
    summ = monitor.summary()
    per_p = summ["median_s"] / args.batch_size
    pps = args.batch_size / summ["median_s"]
    hops = [len(p) - 1 for p in last[3] if p is not None]
    print(f"paths: {total} unwound; median batch "
          f"{summ['median_s'] * 1e3:.2f}ms -> {per_p * 1e6:.2f}us/path "
          f"({pps:,.0f} paths/s, mean {np.mean(hops):.1f} hops)")
    s, t, dist, paths = last
    bad = 0
    for i in range(min(args.validate, len(s))):
        want = dijkstra.pair(engine.g, int(s[i]), int(t[i]))
        if np.isinf(want):
            bad += paths[i] is not None
            continue
        w = path_weight(engine.g, paths[i])   # raises on a broken hop
        if not (w == float(dist[i]) == want):
            bad += 1
    print(f"path validation: {bad} mismatches of {args.validate} "
          "(edge-valid, weight == serve == Dijkstra, exact)")
    assert bad == 0
    return [{
        "section": "serve_paths",
        "graph": f"road{args.nodes}",
        "backend": jax.default_backend(),
        "batch_size": args.batch_size,
        "median_batch_ms": round(summ["median_s"] * 1e3, 3),
        "us_per_path": round(per_p * 1e6, 3),
        "paths_per_s": round(pps, 1),
        "mean_hops": round(float(np.mean(hops)), 1) if hops else 0.0,
    }]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--validate", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=("planner", "fused", "sharded"),
                    default="planner")
    ap.add_argument("--sharded", action="store_true",
                    help="alias for --mode sharded")
    ap.add_argument("--paths", action="store_true",
                    help="also serve exact paths (witness mode + host "
                         "unwind, planner only) and report paths/sec")
    ap.add_argument("--update-batches", type=int, default=0,
                    help="live-traffic rounds after serving (planner)")
    ap.add_argument("--update-frac", type=float, default=0.02,
                    help="fraction of edges perturbed per round")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="perf-record file ('' disables)")
    args = ap.parse_args()
    mode = "sharded" if args.sharded else args.mode
    if args.update_batches and mode != "planner":
        ap.error("--update-batches requires --mode planner")
    if args.paths and mode != "planner":
        ap.error("--paths requires --mode planner")

    t0 = time.perf_counter()
    g = road_like(args.nodes, seed=args.seed)
    print(f"graph: n={g.n} m={g.m} ({time.perf_counter() - t0:.1f}s)")
    t0 = time.perf_counter()
    ix = build_index(g)
    print(f"index: {ix.timings} ({time.perf_counter() - t0:.1f}s)")
    t0 = time.perf_counter()
    engine = None
    if mode == "planner":
        engine = EpochedEngine(g, ix=ix, paths=args.paths)
        dix = engine.dix
    else:
        dix = build_device_index(ix)
    build_s = time.perf_counter() - t0
    print(f"device index: frag_apsp={dix.frag_apsp.shape} "
          f"d_super={dix.d_super.shape} ({build_s:.1f}s)")

    rng = np.random.default_rng(args.seed + 1)
    monitor = StragglerMonitor()
    planner = None
    if mode == "sharded":
        mesh = make_host_mesh()
        fn = lambda s, t: serve_sharded(mesh, dix, s, t)  # noqa: E731
    elif mode == "planner":
        planner = engine.planner
        fn = planner
    else:
        jfn = jax.jit(lambda s, t: serve_step(dix, s, t))
        fn = jfn
    # warm-up before timing: the planner pre-compiles every sub-program
    # at every padded bucket size a batch can produce; the other modes
    # compile their one program on a throwaway batch
    if planner is not None:
        planner.warmup(args.batch_size)
    else:
        s = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        jax.block_until_ready(jnp.asarray(fn(s, t)))
    total_q = 0
    last = None
    for i in range(args.batches):
        s = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        monitor.start()
        out = jax.block_until_ready(jnp.asarray(fn(s, t)))
        monitor.stop()
        total_q += args.batch_size
        last = (np.asarray(s), np.asarray(t), np.asarray(out))
    summ = monitor.summary()
    per_q = summ["median_s"] / args.batch_size
    qps = args.batch_size / summ["median_s"]
    print(f"served {total_q} queries; median batch {summ['median_s']*1e3:.2f}ms "
          f"-> {per_q*1e6:.2f}us/query ({qps:,.0f} qps)")
    if planner is not None:
        print(f"planner buckets (last batch): {planner.last_counts}")
    if args.json:
        prev = latest(args.json, section="serve",
                      graph=f"road{args.nodes}", mode=mode)
        if prev:
            print(f"previous {mode} record: "
                  f"{prev['us_per_query']}us/query")
        append_records(args.json, [{
            "section": "serve",
            "graph": f"road{args.nodes}",
            "mode": mode,
            "backend": jax.default_backend(),
            "batch_size": args.batch_size,
            "median_batch_ms": round(summ["median_s"] * 1e3, 3),
            "us_per_query": round(per_q * 1e6, 3),
            "qps": round(qps, 1),
        }])
        print(f"perf record appended to {args.json}")
    if args.validate:
        s, t, got = last
        bad = 0
        for i in range(min(args.validate, len(s))):
            want = dijkstra.pair(g, int(s[i]), int(t[i]))
            if not (np.isinf(want) and np.isinf(got[i])) \
                    and abs(got[i] - want) > 1e-4 * max(want, 1):
                bad += 1
        print(f"validation: {bad} mismatches of {args.validate}")
        assert bad == 0
    if args.paths:
        records = _paths_loop(engine, args)
        if args.json:
            prev = latest(args.json, section="serve_paths",
                          graph=f"road{args.nodes}")
            if prev:
                print(f"previous paths record: "
                      f"{prev['us_per_path']}us/path")
            append_records(args.json, records)
            print(f"paths record appended to {args.json}")
    if args.update_batches:
        records = _update_loop(engine, args, build_s)
        if args.json:
            append_records(args.json, records)
            print(f"{len(records)} refresh records appended to "
                  f"{args.json}")


if __name__ == "__main__":
    main()
