"""MODEL_FLOPS estimators (roofline §: the 'useful compute' numerator).

LM uses the standard 6*N*D (train) / 2*N*D (inference) parameter-flops
convention with N = active params; GNN/recsys count the dominant matmul
terms explicitly.  These are *model* flops — the ratio against compiled
HLO flops surfaces dispatch/remat/padding waste.
"""
from __future__ import annotations

from ..configs.api import ArchSpec, ShapeCell
from ..models import gnn, recsys, transformer


def model_flops(spec: ArchSpec, cell: ShapeCell) -> float:
    if spec.family == "lm":
        return _lm(spec.model_cfg, cell)
    if spec.family == "gnn":
        return _gnn(spec.model_cfg, cell)
    return _recsys(spec.model_cfg, cell)


def _lm(cfg: transformer.LMConfig, cell: ShapeCell) -> float:
    n_act = cfg.n_active_params()
    d = cell.dims
    if cell.kind == "train":
        tokens = d["seq_len"] * d["global_batch"]
        return 6.0 * n_act * tokens
    if cell.kind == "prefill":
        tokens = d["seq_len"] * d["global_batch"]
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * d["global_batch"]


def _gnn(cfg: gnn.GNNConfig, cell: ShapeCell) -> float:
    d = cell.dims
    n, e, df = d["n_nodes"], d["n_edges"], d["d_feat"]
    h = cfg.d_hidden
    t3 = 2 * e
    if cfg.arch == "graphcast":
        enc = 2.0 * (n * df * h + n * h * h + e * 4 * h + e * h * h)
        per_layer = 2.0 * (e * (3 * h) * h + e * h * h
                           + n * (2 * h) * h + n * h * h)
        dec = 2.0 * n * (h * h + h * cfg.n_out)
        fwd = enc + cfg.n_layers * per_layer + dec
    elif cfg.arch == "dimenet":
        embed = 2.0 * e * (df + cfg.n_radial) * h + 2.0 * e * h * h
        nsr = cfg.n_spherical * cfg.n_radial
        per_layer = 2.0 * (e * h * h                 # proj_kj
                           + t3 * nsr * cfg.n_bilinear
                           + t3 * cfg.n_bilinear * h * h  # bilinear einsum
                           + e * 2 * h * h)          # msg mlp
        out = 2.0 * n * (h * h + h * cfg.n_out)
        fwd = embed + cfg.n_layers * per_layer + out
    elif cfg.arch == "graphsage":
        d_in = df
        fwd = 0.0
        for _ in range(cfg.n_layers):
            fwd += 2.0 * n * (2 * d_in) * h
            d_in = h
        fwd += 2.0 * n * h * cfg.n_classes
    else:  # gat
        d_in = df
        fwd = 0.0
        for _ in range(cfg.n_layers):
            fwd += 2.0 * n * d_in * cfg.n_heads * cfg.d_hidden
            fwd += 4.0 * e * cfg.n_heads * cfg.d_hidden
            d_in = cfg.n_heads * cfg.d_hidden
        fwd += 2.0 * n * d_in * cfg.n_classes
    return 3.0 * fwd if cell.kind == "train" else fwd


def _recsys(cfg: recsys.RecsysConfig, cell: ShapeCell) -> float:
    d = cell.dims
    b = d["batch"]
    d_in = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    dims = (d_in,) + cfg.mlp_dims + (1,)
    mlp = sum(2.0 * a * bb for a, bb in zip(dims[:-1], dims[1:]))
    fwd = b * mlp
    if cell.kind == "retrieval":
        fwd = mlp + 2.0 * d["n_candidates"] * cfg.mlp_dims[-1]
    return 3.0 * fwd if cell.kind == "train" else fwd
