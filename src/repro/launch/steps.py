"""Step functions (train/prefill/decode/serve) composed from models +
optimizer, with grad accumulation and sharding-aware carries.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..models import gnn, recsys, transformer
from ..models.common import Shardings
from ..optim import AdamWState, adamw_update


def constrain_tree(tree, specs, sh: Shardings):
    if sh.mesh is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x, p: jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(sh.mesh, p)), tree, specs)


def make_grad_accum_step(loss_fn: Callable, split_batch: Callable,
                         n_micro: int, param_specs, sh: Shardings,
                         lr: float = 3e-4, serialize_update: bool = False,
                         accum_dtype=jnp.float32):
    """Generic train step: grads accumulated over n_micro microbatches
    (fp32 by default, sharded like params), then one AdamW update.

    loss_fn(params, microbatch) -> scalar loss
    split_batch(batch, n_micro) -> pytree with leading [n_micro, ...]
    accum_dtype: bf16 halves the accumulation buffers; used by the 104B
    arch where the fp32 tree is the last GB over the HBM budget (Adam's
    per-coordinate normalisation absorbs the rounding; EXPERIMENTS.md
    §Perf M5).
    """

    def step(params, opt: AdamWState, batch):
        if n_micro <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grad_scale = 1.0
        else:
            micro = split_batch(batch, n_micro)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def body(carry, mb):
                acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(accum_dtype), acc, g)
                if param_specs is not None:
                    acc = constrain_tree(acc, param_specs, sh)
                return acc, loss

            grads, losses = jax.lax.scan(body, zero, micro)
            # the 1/n_micro mean folds into the optimizer's clip scale —
            # tree_map(g / n) would copy the full fp32 tree
            grad_scale = 1.0 / n_micro
            loss = jnp.mean(losses)
        new_params, new_opt, metrics = adamw_update(
            params, grads, opt, lr=lr, serialize=serialize_update,
            grad_scale=grad_scale)
        # donated params/opt force output shardings to match inputs; no
        # extra constraint copies needed here
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return step


# ---------------------------------------------------------------------------
# LM steps
# ---------------------------------------------------------------------------
def lm_train_step(cfg: transformer.LMConfig, sh: Shardings,
                  n_micro: int, serialize_update: bool = False,
                  accum_dtype=jnp.float32):
    # gradients accumulate in the *optimizer-state* sharding: under
    # ZeRO-1 that reduce-scatters per-micro grads onto the data shard
    # instead of all-reducing against replicated params
    specs = transformer.param_specs(cfg, sh, for_opt_state=True)

    def loss_fn(params, tokens):
        return transformer.forward_loss(cfg, sh, params, tokens)

    def split(tokens, n):
        b, t = tokens.shape
        return tokens.reshape(n, b // n, t)

    return make_grad_accum_step(loss_fn, split, n_micro, specs, sh,
                                serialize_update=serialize_update,
                                accum_dtype=accum_dtype)


def lm_prefill_step(cfg: transformer.LMConfig, sh: Shardings):
    def step(params, tokens):
        return transformer.prefill(cfg, sh, params, tokens)
    return step


def lm_decode_step(cfg: transformer.LMConfig, sh: Shardings):
    def step(params, cache, token):
        return transformer.decode_step(cfg, sh, params, cache, token)
    return step


# ---------------------------------------------------------------------------
# GNN / recsys steps
# ---------------------------------------------------------------------------
def gnn_train_step(cfg: gnn.GNNConfig, sh: Shardings):
    def loss_fn(params, batch):
        return gnn.forward_loss(cfg, sh, params, batch)
    return make_grad_accum_step(loss_fn, None, 1, None, sh)


def recsys_train_step(cfg: recsys.RecsysConfig, sh: Shardings):
    specs = recsys.param_specs(cfg, sh)

    def loss_fn(params, batch):
        return recsys.forward_loss(cfg, sh, params, batch)
    return make_grad_accum_step(loss_fn, None, 1, specs, sh)


def recsys_serve_step(cfg: recsys.RecsysConfig, sh: Shardings):
    def step(params, batch):
        return recsys.forward_logits(cfg, sh, params, batch)
    return step


def recsys_retrieval_step(cfg: recsys.RecsysConfig, sh: Shardings,
                          top_k: int = 100):
    def step(params, batch):
        return recsys.retrieval_scores(cfg, sh, params, batch,
                                       top_k=top_k)
    return step
