import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): AOT lower + compile every
(architecture x input shape) on the production meshes, record memory /
cost / collective analysis for the roofline (deliverable g).

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The XLA_FLAGS line above MUST run before any jax import: jax locks the
platform device count at first init.  Results land in
experiments/dryrun/<arch>__<shape>__<mesh>.json (skip if present unless
--force), so the full sweep is resumable.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from ..configs import get_arch, list_archs           # noqa: E402
from . import hloanalysis, traffic                   # noqa: E402
from .cells import build_cell                        # noqa: E402
from .mesh import make_production_mesh               # noqa: E402

# TPU v5e hardware constants (system prompt ROOFLINE ANALYSIS)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[8,128]' or '(f32[2], bf16[4,4])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind, from the
    partitioned HLO: sum of result-shape bytes per op (start ops only;
    '-done' halves of async pairs are skipped to avoid double count)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?\S+\s*=\s*((?:\([^)]*\))|(?:\S+))\s+(\S+)\(", s)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if opname == kind or opname == kind + "-start":
                out[kind] += _shape_bytes(shape_str)
                counts[kind] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             outdir: str, force: bool = False) -> dict:
    path = os.path.join(outdir, f"{arch_id}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.size
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
           "n_chips": n_chips, "ok": False}
    try:
        bundle = build_cell(arch_id, shape_name, mesh)
        t0 = time.perf_counter()
        with mesh:
            jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             donate_argnums=bundle.donate_argnums)
            lowered = jitted.lower(*bundle.args)
            rec["lower_s"] = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        rec["memory"]["total_device_bytes"] = (
            rec["memory"].get("argument_size_in_bytes", 0)
            + rec["memory"].get("output_size_in_bytes", 0)
            + rec["memory"].get("temp_size_in_bytes", 0))
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost_xla"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed")}
        hlo = compiled.as_text()
        rec["hlo_bytes"] = len(hlo)
        # loop-aware per-device analysis (hloanalysis module): XLA's own
        # cost_analysis counts while bodies once — useless for scanned
        # models (EXPERIMENTS.md §Roofline methodology)
        ana = hloanalysis.analyze(hlo)
        rec["analysis"] = {
            "dot_flops": ana.flops,
            "hbm_bytes_measured": ana.bytes,
            "cpu_copy_bytes": ana.copy_bytes,
            "unknown_trip_counts": ana.unknown_trips,
            "collective_bytes": {k: v for k, v in ana.collectives.items()
                                 if v},
        }
        mesh_obj = make_production_mesh(multi_pod=multi)
        tp = mesh_obj.shape.get("model", 1)
        bytes_model = traffic.analytic_bytes(
            get_arch(arch_id), get_arch(arch_id).shape(shape_name),
            n_chips, tp=tp)
        rec["analysis"]["hbm_bytes_model"] = bytes_model
        flops_dev = ana.flops
        coll_dev = ana.collective_bytes
        rec["model_flops"] = bundle.model_flops
        rec["notes"] = bundle.notes
        rec["roofline"] = {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_model / HBM_BW,
            "collective_s": coll_dev / LINK_BW,
        }
        terms = dict(rec["roofline"])
        rec["roofline"]["dominant"] = max(terms, key=terms.get)
        total_hlo_flops = flops_dev * n_chips
        rec["roofline"]["model_vs_hlo_flops"] = (
            bundle.model_flops / total_hlo_flops
            if total_hlo_flops else float("nan"))
        # step time bound = max of the three terms; roofline fraction =
        # useful-model-compute time / bounded step time
        step_s = max(terms.values())
        ideal_s = bundle.model_flops / (n_chips * PEAK_FLOPS)
        rec["roofline"]["step_time_bound_s"] = step_s
        rec["roofline"]["roofline_fraction"] = (
            ideal_s / step_s if step_s > 0 else float("nan"))
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record per-cell failures
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else "FAIL"
    print(f"[{status}] {arch_id} x {shape_name} x {mesh_kind} "
          f"lower={rec.get('lower_s', 0):.1f}s "
          f"compile={rec.get('compile_s', 0):.1f}s "
          f"{rec.get('error', '')}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    meshes = (["single", "multipod"] if args.mesh == "both"
              else [args.mesh])
    cells = []
    if args.all:
        for a in list_archs():
            for s in get_arch(a).shapes:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))
    n_ok = 0
    for a, s in cells:
        for mk in meshes:
            rec = run_cell(a, s, mk, args.out, force=args.force)
            n_ok += bool(rec["ok"])
    print(f"done: {n_ok}/{len(cells) * len(meshes)} cells OK", flush=True)


if __name__ == "__main__":
    main()
