"""End-to-end training driver (deliverable b): real data pipeline,
sharded train steps, checkpoint/restart, straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --steps 50 --reduced --ckpt /tmp/ckpt

--reduced shrinks the arch to a CPU-trainable size (same code path:
scan over layers, grad accumulation, sharded AdamW) so the driver runs
end-to-end in this container; on TPU the full config trains unchanged.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_arch
from ..data import lm_batches, recsys_batches, gnn_full_batch
from ..models import gnn, recsys, transformer
from ..models.common import Shardings
from ..optim import adamw_init
from ..runtime import StragglerMonitor
from .mesh import make_host_mesh
from . import steps


def reduced_lm(cfg: transformer.LMConfig) -> transformer.LMConfig:
    return dataclasses.replace(
        cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, n_experts=min(cfg.n_experts, 4) if cfg.moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.moe else 0, dtype=jnp.float32)


def reduced_gnn(cfg: gnn.GNNConfig) -> gnn.GNNConfig:
    return dataclasses.replace(cfg, n_layers=2, d_hidden=32, d_feat=16,
                               n_out=min(cfg.n_out, 4))


def reduced_recsys(cfg: recsys.RecsysConfig) -> recsys.RecsysConfig:
    return dataclasses.replace(cfg, rows_per_field=1000, n_sparse=8,
                               mlp_dims=(64, 32))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    mesh = make_host_mesh()
    sh = Shardings(mesh=mesh)
    monitor = StragglerMonitor()

    if spec.family == "lm":
        cfg = reduced_lm(spec.model_cfg) if args.reduced else spec.model_cfg
        params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
        step_fn = steps.lm_train_step(cfg, sh, n_micro=1)
        data = lm_batches(args.batch, args.seq, cfg.vocab, seed=args.seed)
        batches = (jnp.asarray(b) for b in data)
    elif spec.family == "gnn":
        cfg = reduced_gnn(spec.model_cfg) if args.reduced else spec.model_cfg
        params = gnn.init_params(cfg, jax.random.PRNGKey(args.seed))
        step_fn = steps.gnn_train_step(cfg, sh)
        from ..core.graph import road_like
        g = road_like(512, seed=args.seed)
        batch = gnn_full_batch(g, cfg.d_feat, cfg.n_classes,
                               seed=args.seed, n_out=cfg.n_out)
        batches = iter(lambda: {k: jnp.asarray(v)
                                for k, v in batch.items()}, None)
    else:
        cfg = (reduced_recsys(spec.model_cfg) if args.reduced
               else spec.model_cfg)
        params = recsys.init_params(cfg, jax.random.PRNGKey(args.seed))
        step_fn = steps.recsys_train_step(cfg, sh)
        data = recsys_batches(args.batch, cfg.n_sparse,
                              cfg.rows_per_field, cfg.hots_per_field,
                              seed=args.seed)
        batches = ({k: jnp.asarray(v) for k, v in b.items()}
                   for b in data)

    opt = adamw_init(params)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    start = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        start, (params, opt) = ckpt.restore((params, opt))
        print(f"restored step {start}")

    losses = []
    for step in range(start, args.steps):
        batch = next(batches)
        monitor.start()
        params, opt, metrics = jit_step(params, opt, batch)
        loss = float(metrics["loss"])
        monitor.stop()
        losses.append(loss)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt))
    if ckpt is not None:
        ckpt.save(args.steps, (params, opt))
    print("straggler summary:", monitor.summary())
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    assert np.isfinite(losses[-1]), "training diverged"


if __name__ == "__main__":
    main()
