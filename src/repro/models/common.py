"""Shared model building blocks: norms, RoPE, attention, sharded CE.

Sharding is expressed through ``Shardings``: a tiny helper bound to a
mesh that turns PartitionSpecs into with_sharding_constraint calls and
adapts to 2D (data, model) vs 3D (pod, data, model) meshes — the pod
axis simply joins the data axis for batch/FSDP purposes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Shardings:
    mesh: Optional[Mesh]

    @property
    def dp(self):
        """Batch / FSDP axes: ('pod','data') on multi-pod, ('data',)."""
        if self.mesh is None:
            return None
        names = self.mesh.axis_names
        return tuple(a for a in ("pod", "data") if a in names) or None

    @property
    def tp(self):
        if self.mesh is None:
            return None
        return "model" if "model" in self.mesh.axis_names else None

    def spec(self, *axes) -> P:
        return P(*axes)

    def constrain(self, x: jax.Array, *axes) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*axes)))

    def named(self, *axes) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*axes))


# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [.. T] -> (cos, sin) each [..., T, head_dim/2] f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., T, n_heads, head_dim]; cos/sin broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, q_offset: jax.Array | int = 0,
                  kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Grouped-query attention.

    q [B, Tq, H, dh]; k/v [B, Tk, KV, dh]; H = KV * group.
    ``q_offset``: absolute position of q[0] (decode: Tk_filled - 1).
    ``kv_len``: number of valid cache slots (decode masking).
    Returns [B, Tq, H, dh].
    """
    b, tq, h, dh = q.shape
    _, tk, kv, _ = k.shape
    group = h // kv
    qg = q.reshape(b, tq, kv, group, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = jnp.arange(tq)[:, None] + q_offset
        kpos = jnp.arange(tk)[None, :]
        mask = kpos <= qpos                      # [tq, tk]
        if kv_len is not None:
            mask = mask & (kpos < kv_len)
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    elif kv_len is not None:
        mask = jnp.arange(tk) < kv_len                   # [tk]
        scores = jnp.where(mask[None, None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, tq, h, dh)


def cross_entropy_vocab_sharded(logits: jax.Array, labels: jax.Array,
                                sh: Shardings) -> jax.Array:
    """Mean CE with logits [B, T, V] sharded on V over the model axis.

    Written with plain reductions over V: under SPMD the max/sum reduce
    over the sharded vocab axis lowers to one all-reduce each — the full
    logits are never gathered to one device.
    """
    logits = logits.astype(jnp.float32)
    logits = sh.constrain(logits, sh.dp, None, sh.tp)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    # gold logit via gather along the sharded vocab axis: lowers to a
    # masked local gather + all-reduce, without materialising a second
    # [tokens, V/shard] one-hot buffer
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def causal_lm_loss(logits: jax.Array, tokens: jax.Array,
                   sh: Shardings) -> jax.Array:
    """Next-token prediction: logits[:, :-1] vs tokens[:, 1:]."""
    return cross_entropy_vocab_sharded(logits[:, :-1], tokens[:, 1:], sh)
