"""Wide & Deep recommender (Cheng et al. 2016) with huge sparse tables.

JAX has no native EmbeddingBag: lookups are ``jnp.take`` over row-sharded
tables + ``segment_sum`` bag reduction — built here as a first-class op
(assignment note).  Tables are row-sharded on the 'model' axis; a lookup
on sharded rows lowers to SPMD gather collectives (the hillclimb target
for the recsys cells).

Shapes:
  train_batch / serve_p99 / serve_bulk : [B, F, H] multi-hot ids
  retrieval_cand: one user against n_candidates item vectors (dot + top-k)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .common import Shardings


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_sparse: int = 40            # categorical fields
    n_dense: int = 13
    embed_dim: int = 32
    rows_per_field: int = 1_000_000
    hots_per_field: int = 2       # multi-hot width H
    mlp_dims: Tuple[int, ...] = (1024, 512, 256)
    interaction: str = "concat"
    dtype: Any = jnp.float32


def init_params(cfg: RecsysConfig, key) -> Dict:
    ks = jax.random.split(key, 8)
    d_in = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    mlp = {}
    dims = (d_in,) + cfg.mlp_dims + (1,)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        kk = jax.random.fold_in(ks[0], i)
        mlp[f"w{i}"] = (jax.random.normal(kk, (a, b), jnp.float32)
                        * a ** -0.5).astype(cfg.dtype)
        mlp[f"b{i}"] = jnp.zeros((b,), cfg.dtype)
    return {
        # one big [F * rows, dim] table (fields offset into it)
        "table": (jax.random.normal(
            ks[1], (cfg.n_sparse * cfg.rows_per_field, cfg.embed_dim),
            jnp.float32) * 0.01).astype(cfg.dtype),
        # wide: one scalar weight per table row + dense weights
        "wide_table": jnp.zeros((cfg.n_sparse * cfg.rows_per_field,),
                                cfg.dtype),
        "wide_dense": jnp.zeros((cfg.n_dense,), cfg.dtype),
        "mlp": mlp,
        "bias": jnp.zeros((), cfg.dtype),
    }


def param_specs(cfg: RecsysConfig, sh: Shardings) -> Dict:
    P_ = sh.spec
    mlp = {k: P_(None, None) if k.startswith("w") else P_(None)
           for k in init_mlp_keys(cfg)}
    return {
        "table": P_(sh.tp, None),       # row-sharded on 'model'
        "wide_table": P_(sh.tp),
        "wide_dense": P_(None),
        "mlp": mlp,
        "bias": P_(),
    }


def init_mlp_keys(cfg: RecsysConfig):
    dims = (cfg.n_dense + cfg.n_sparse * cfg.embed_dim,) + cfg.mlp_dims \
        + (1,)
    out = []
    for i in range(len(dims) - 1):
        out += [f"w{i}", f"b{i}"]
    return out


# ---------------------------------------------------------------------------
def embedding_bag(table: jax.Array, ids: jax.Array,
                  weights: jax.Array | None = None,
                  combiner: str = "mean") -> jax.Array:
    """EmbeddingBag built from take + segment_sum.

    ids [B, F, H] (global row ids); returns [B, F, dim].  The segment
    formulation (rather than reshape+mean) keeps the op shape-identical
    to the ragged/offsets variant used by the data pipeline tests.
    """
    b, f, h = ids.shape
    flat = ids.reshape(-1)
    emb = jnp.take(table, flat, axis=0)          # [B*F*H, dim]
    if weights is not None:
        emb = emb * weights.reshape(-1, 1)
    seg = jnp.repeat(jnp.arange(b * f), h)
    out = jax.ops.segment_sum(emb, seg, num_segments=b * f)
    if combiner == "mean":
        out = out / h
    return out.reshape(b, f, -1)


def embedding_bag_ragged(table: jax.Array, ids: jax.Array,
                         offsets: jax.Array, n_bags: int,
                         combiner: str = "sum") -> jax.Array:
    """True ragged EmbeddingBag (torch.nn.EmbeddingBag semantics):
    ids [nnz], offsets [n_bags] (start of each bag)."""
    emb = jnp.take(table, ids, axis=0)
    seg = jnp.searchsorted(offsets, jnp.arange(ids.shape[0]),
                           side="right") - 1
    out = jax.ops.segment_sum(emb, seg, num_segments=n_bags)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, out.dtype), seg,
                                  num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def forward_logits(cfg: RecsysConfig, sh: Shardings, params: Dict,
                   batch: Dict) -> jax.Array:
    """batch: sparse_ids [B, F, H] (field-local), dense [B, n_dense]."""
    ids = batch["sparse_ids"]
    b = ids.shape[0]
    offs = (jnp.arange(cfg.n_sparse, dtype=ids.dtype)
            * cfg.rows_per_field)[None, :, None]
    gids = ids + offs
    emb = embedding_bag(params["table"], gids)       # [B, F, dim]
    emb = sh.constrain(emb, sh.dp, None, None)
    deep_in = jnp.concatenate(
        [batch["dense"].astype(cfg.dtype), emb.reshape(b, -1)], axis=-1)
    x = deep_in
    n = len([k for k in params["mlp"] if k.startswith("w")])
    for i in range(n):
        x = x @ params["mlp"][f"w{i}"] + params["mlp"][f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    deep = x[:, 0]
    # wide: sum of per-row weights + linear dense
    wide_sp = jnp.take(params["wide_table"], gids.reshape(b, -1),
                       axis=0).sum(-1)
    wide = wide_sp + batch["dense"].astype(cfg.dtype) @ params["wide_dense"]
    return deep + wide + params["bias"]


def forward_loss(cfg: RecsysConfig, sh: Shardings, params: Dict,
                 batch: Dict) -> jax.Array:
    logits = forward_logits(cfg, sh, params, batch).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    # sigmoid BCE
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_scores(cfg: RecsysConfig, sh: Shardings, params: Dict,
                     batch: Dict, top_k: int = 100
                     ) -> Tuple[jax.Array, jax.Array]:
    """One query against n_candidates: batched dot + top-k (no loop).

    The query tower reuses the deep MLP up to its penultimate layer; the
    candidate matrix [n_cand, d_last] is an input (precomputed item
    embeddings, sharded over the flat mesh)."""
    ids = batch["sparse_ids"]                      # [1, F, H]
    offs = (jnp.arange(cfg.n_sparse, dtype=ids.dtype)
            * cfg.rows_per_field)[None, :, None]
    emb = embedding_bag(params["table"], ids + offs)
    q = jnp.concatenate([batch["dense"].astype(cfg.dtype),
                         emb.reshape(1, -1)], -1)
    n = len([k for k in params["mlp"] if k.startswith("w")])
    for i in range(n - 1):                         # stop before logit layer
        q = q @ params["mlp"][f"w{i}"] + params["mlp"][f"b{i}"]
        q = jax.nn.relu(q)
    cand = batch["candidates"]                     # [n_cand, d_last]
    flat = tuple(sh.mesh.axis_names) if sh.mesh is not None else None
    cand = sh.constrain(cand, flat, None) if flat else cand
    scores = (cand @ q[0]).astype(jnp.float32)     # [n_cand]
    return jax.lax.top_k(scores, top_k)
