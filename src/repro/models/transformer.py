"""Decoder-only transformer LM: dense + MoE, GQA, RoPE, SwiGLU, RMSNorm.

Covers the five assigned LM architectures (granite-8b, command-r-plus,
phi4-mini, llama4-scout MoE, granite-moe).  Design notes:

  * scan-over-layers with stacked [L, ...] weights keeps the HLO small
    (critical when compiling against 512 partitions) and remat wraps the
    layer body.
  * training shards: batch on (pod, data); params FSDP on 'data' +
    tensor-parallel on 'model' (heads / d_ff / vocab); kv-heads (8 <
    model axis) replicate on 'model'; the pod axis replicates params and
    all-reduces grads (2-level DP).
  * prefill uses q-chunked attention (fixed [chunk, T] score tiles) so
    32k-token prefill never materialises a T x T score matrix.
  * decode keeps a [L, B, Tmax, KV, dh] cache, sequence-sharded when the
    batch axis cannot cover the mesh (long-context cells).
  * MoE uses sort-free gather/scatter dispatch with static capacity:
    position-in-expert comes from a cumsum over the one-hot [N, E] mask
    (cheap, no D factor), the heavy tensors only move through gathers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from .common import (Shardings, apply_rope, causal_lm_loss, gqa_attention,
                     rms_norm, rope_angles)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    rope_theta: float = 500_000.0
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk: int = 1024           # q-chunk for long prefill
    # memory levers (see EXPERIMENTS.md §Perf):
    gather_fsdp_in_body: bool = False  # re-gather FSDP weights per layer
    seq_shard_activations: bool = False  # sequence-parallel residual
    # ZeRO stage: 3 = params+opt FSDP-sharded on 'data' (default);
    # 1 = params replicated on 'data' (no per-layer weight all-gathers),
    # optimizer state still sharded.  Right for models whose bf16 params
    # fit per-device (EXPERIMENTS.md §Perf P1).
    zero_stage: int = 3
    # remat policy: True = full per-layer recompute; "save_tp_outputs"
    # keeps the two all-reduced tensors per layer so the recompute pass
    # skips their collectives (costs 2 x [tokens, d] bf16 per layer)
    remat_policy: str = "full"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 256) * 256

    def n_params(self) -> int:
        """Total parameter count (for 6ND model-FLOPs accounting)."""
        d, f, h, kv, dh = (self.d_model, self.d_ff, self.n_heads,
                           self.n_kv_heads, self.head_dim)
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.moe:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return (self.n_layers * per_layer + self.vocab_padded * d + d)

    def n_active_params(self) -> int:
        if not self.moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_ffn = 3 * d * f * self.top_k + d * self.n_experts
        moe_ffn = self.n_experts * 3 * d * f + d * self.n_experts
        return self.n_params() - self.n_layers * (moe_ffn - dense_ffn)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def init_params(cfg: LMConfig, key: jax.Array) -> Dict:
    d, f, h, kv, dh = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads,
                       cfg.head_dim)
    L, V = cfg.n_layers, cfg.vocab_padded
    k = jax.random.split(key, 10)
    s = lambda *sh: 1.0 / jnp.sqrt(jnp.prod(jnp.array(sh[-1:])))
    dt = cfg.dtype

    def normal(kk, shape, scale):
        return (jax.random.normal(kk, shape, jnp.float32) * scale).astype(dt)

    layers = {
        "attn_norm": jnp.ones((L, d), dt),
        "ffn_norm": jnp.ones((L, d), dt),
        "wq": normal(k[0], (L, d, h, dh), d ** -0.5),
        "wk": normal(k[1], (L, d, kv, dh), d ** -0.5),
        "wv": normal(k[2], (L, d, kv, dh), d ** -0.5),
        "wo": normal(k[3], (L, h, dh, d), (h * dh) ** -0.5),
    }
    if cfg.moe:
        E = cfg.n_experts
        layers.update({
            "router": normal(k[4], (L, d, E), d ** -0.5),
            "w_gate": normal(k[5], (L, E, d, f), d ** -0.5),
            "w_up": normal(k[6], (L, E, d, f), d ** -0.5),
            "w_down": normal(k[7], (L, E, f, d), f ** -0.5),
        })
    else:
        layers.update({
            "w_gate": normal(k[5], (L, d, f), d ** -0.5),
            "w_up": normal(k[6], (L, d, f), d ** -0.5),
            "w_down": normal(k[7], (L, f, d), f ** -0.5),
        })
    return {
        # tied in/out embedding: small init keeps initial logits ~O(1)
        "embed": normal(k[8], (V, d), d ** -0.5),
        "final_norm": jnp.ones((d,), dt),
        "layers": layers,
    }


def param_specs(cfg: LMConfig, sh: Shardings, *,
                for_opt_state: bool = False) -> Dict:
    """PartitionSpec tree matching init_params output.

    Under ZeRO-1 (zero_stage=1) parameters replicate over 'data' while
    optimizer state keeps the data shard (``for_opt_state=True``)."""
    tp = sh.tp
    fsdp = "data" if (sh.mesh is not None
                      and "data" in sh.mesh.axis_names) else None
    if cfg.zero_stage == 1 and not for_opt_state:
        fsdp = None
    tp_size = (sh.mesh.shape["model"]
               if sh.mesh is not None and tp else 1)
    heads_ok = cfg.n_heads % max(tp_size, 1) == 0
    h_tp = tp if heads_ok else None
    P_ = sh.spec
    layers = {
        "attn_norm": P_(None, None),
        "ffn_norm": P_(None, None),
        "wq": P_(None, fsdp, h_tp, None),
        "wk": P_(None, fsdp, None, None),
        "wv": P_(None, fsdp, None, None),
        "wo": P_(None, h_tp, None, fsdp),
    }
    if cfg.moe:
        e_tp = tp if cfg.n_experts % max(tp_size, 1) == 0 else None
        layers.update({
            "router": P_(None, fsdp, None),
            "w_gate": P_(None, e_tp, fsdp, None),
            "w_up": P_(None, e_tp, fsdp, None),
            "w_down": P_(None, e_tp, None, fsdp),
        })
    else:
        f_tp = tp if cfg.d_ff % max(tp_size, 1) == 0 else None
        layers.update({
            "w_gate": P_(None, fsdp, f_tp),
            "w_up": P_(None, fsdp, f_tp),
            "w_down": P_(None, f_tp, fsdp),
        })
    v_tp = tp if cfg.vocab_padded % max(tp_size, 1) == 0 else None
    return {
        # 2D-sharded embedding: vocab on model, d_model on data (FSDP) —
        # the unsharded-on-data variant costs ~2 GB/device in fp32
        # optimizer/grad copies on the 256k-vocab archs
        "embed": P_(v_tp, fsdp),
        "final_norm": P_(None),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _attention_block(cfg: LMConfig, sh: Shardings, lw: Dict, x: jax.Array,
                     cos: jax.Array, sin: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence causal attention, q-chunked for long T.

    Returns (out, k, v) so prefill can cache k/v without recompute (the
    training path simply drops them — dead values are pruned)."""
    b, t, d = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, lw["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, lw["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, lw["wv"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = sh.constrain(q, sh.dp, None, sh.tp, None)
    if t <= cfg.attn_chunk or t % cfg.attn_chunk != 0:
        o = gqa_attention(q, k, v, causal=True)
    else:
        nc = t // cfg.attn_chunk

        def chunk(carry, i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * cfg.attn_chunk,
                                              cfg.attn_chunk, axis=1)
            o = gqa_attention(qs, k, v, causal=True,
                              q_offset=i * cfg.attn_chunk)
            return carry, o

        _, chunks = jax.lax.scan(chunk, 0, jnp.arange(nc))
        o = jnp.moveaxis(chunks, 0, 1).reshape(b, t, cfg.n_heads,
                                               cfg.head_dim)
    o = sh.constrain(o, sh.dp, None, sh.tp, None)
    return jnp.einsum("bthk,hkd->btd", o, lw["wo"]), k, v


def _dense_ffn(cfg: LMConfig, sh: Shardings, lw: Dict,
               x: jax.Array) -> jax.Array:
    g = jnp.einsum("btd,df->btf", x, lw["w_gate"])
    u = jnp.einsum("btd,df->btf", x, lw["w_up"])
    hidden = jax.nn.silu(g) * u
    hidden = sh.constrain(hidden, sh.dp, None, sh.tp)
    return jnp.einsum("btf,fd->btd", hidden, lw["w_down"])


def _moe_ffn(cfg: LMConfig, sh: Shardings, lw: Dict, x: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE with static-capacity gather/scatter dispatch.

    Returns (output, aux_loss)."""
    b, t, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = b * t
    xf = x.reshape(N, d)
    logits = jnp.einsum("nd,de->ne", xf, lw["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                     # [N, K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    # Switch-style load-balance aux loss
    density = jnp.mean(jax.nn.one_hot(eidx[:, 0], E), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_prob)
    # ---- dispatch -----------------------------------------------------
    cap = int(cfg.capacity_factor * N * K / E)
    cap = max(8, -(-cap // 8) * 8)
    flat_e = eidx.reshape(-1)                                # [N*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # [N*K, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                     # pos in expert
    pos = jnp.sum(pos * onehot, axis=-1)                     # [N*K]
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, E * cap)      # overflow slot
    token_of = jnp.repeat(jnp.arange(N), K)
    # inverse map: slot -> token (int scatter, small)
    slot_token = jnp.zeros(E * cap + 1, jnp.int32).at[slot].set(
        token_of, mode="drop")
    slot_valid = jnp.zeros(E * cap + 1, jnp.bool_).at[slot].set(
        keep, mode="drop")
    buf = xf[slot_token[:E * cap]] * slot_valid[:E * cap, None]
    buf = buf.reshape(E, cap, d)
    buf = sh.constrain(buf, sh.tp, None, None)
    # ---- expert compute -------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, lw["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, lw["w_up"])
    hidden = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", hidden, lw["w_down"])
    y = sh.constrain(y, sh.tp, None, None)
    # ---- combine ----------------------------------------------------------
    yf = y.reshape(E * cap, d)
    gathered = yf[jnp.minimum(slot, E * cap - 1)]            # [N*K, d]
    gathered = gathered * (keep[:, None] & (slot < E * cap)[:, None])
    contrib = gathered.reshape(N, K, d) * gate[..., None].astype(x.dtype)
    out = jnp.sum(contrib, axis=1).reshape(b, t, d)
    return out, aux


def _gather_lw(cfg: LMConfig, sh: Shardings, lw: Dict) -> Dict:
    """Re-constrain the per-layer weight slices to drop the FSDP axis.

    Placing the all-gather on the *sliced* (loop-index-dependent) weight
    keeps it inside the scan body, so while-loop-invariant code motion
    cannot hoist a full [L, ...] unsharded weight stack into live memory
    (the 13 GB/device regression measured on command-r; EXPERIMENTS.md
    §Perf iteration M1)."""
    if sh.mesh is None or not cfg.gather_fsdp_in_body:
        return lw
    tp_size = sh.mesh.shape.get("model", 1)
    h_tp = sh.tp if cfg.n_heads % max(tp_size, 1) == 0 else None
    specs = {
        "attn_norm": (None,), "ffn_norm": (None,),
        "wq": (None, h_tp, None), "wk": (None, None, None),
        "wv": (None, None, None), "wo": (h_tp, None, None),
    }
    if cfg.moe:
        e_tp = sh.tp if cfg.n_experts % max(tp_size, 1) == 0 else None
        specs.update({"router": (None, None),
                      "w_gate": (e_tp, None, None),
                      "w_up": (e_tp, None, None),
                      "w_down": (e_tp, None, None)})
    else:
        f_tp = sh.tp if cfg.d_ff % max(tp_size, 1) == 0 else None
        specs.update({"w_gate": (None, f_tp), "w_up": (None, f_tp),
                      "w_down": (f_tp, None)})
    return {k: sh.constrain(v, *specs[k]) for k, v in lw.items()}


def _res_spec(cfg: LMConfig, sh: Shardings):
    """Residual-stream sharding: sequence-parallel when enabled."""
    if cfg.seq_shard_activations:
        return (sh.dp, sh.tp, None)
    return (sh.dp, None, None)


def _layer(cfg: LMConfig, sh: Shardings, x: jax.Array, lw: Dict,
           cos: jax.Array, sin: jax.Array):
    """-> (h, aux_loss, k, v)."""
    lw = _gather_lw(cfg, sh, lw)
    attn, k, v = _attention_block(cfg, sh, lw,
                                  rms_norm(x, lw["attn_norm"]), cos, sin)
    # the two TP all-reduce outputs are checkpoint-named so the
    # save_only_these_names remat policy can keep them and skip
    # re-all-reducing in the recompute pass (EXPERIMENTS.md §Perf P1b)
    attn = checkpoint_name(attn, "attn_out")
    h = x + attn
    h = sh.constrain(h, *_res_spec(cfg, sh))
    hin = rms_norm(h, lw["ffn_norm"])
    if cfg.moe:
        out, aux = _moe_ffn(cfg, sh, lw, hin)
    else:
        out, aux = _dense_ffn(cfg, sh, lw, hin), jnp.float32(0.0)
    out = checkpoint_name(out, "ffn_out")
    h = h + out
    return sh.constrain(h, *_res_spec(cfg, sh)), aux, k, v


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------
def forward_loss(cfg: LMConfig, sh: Shardings, params: Dict,
                 tokens: jax.Array) -> jax.Array:
    """Causal-LM loss for a [B, T] token batch."""
    b, t = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    h = sh.constrain(h, *_res_spec(cfg, sh))
    cos, sin = rope_angles(jnp.arange(t), cfg.head_dim, cfg.rope_theta)

    def body(carry, lw):
        h = carry
        h, aux, _, _ = _layer(cfg, sh, h, lw, cos, sin)
        return h, aux

    if cfg.remat and cfg.remat_policy == "save_tp_outputs":
        layer_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out"))
    elif cfg.remat:
        layer_fn = jax.checkpoint(body)
    else:
        layer_fn = body
    h, auxs = jax.lax.scan(layer_fn, h, params["layers"])
    h = rms_norm(h, params["final_norm"])
    # re-assert the 2D embed sharding at the logits use-site so the
    # cotangent (embed grad) comes back sharded rather than as a full
    # [V/tp, D] fp32 buffer
    fsdp = ("data" if sh.mesh is not None
            and "data" in sh.mesh.axis_names else None)
    emb = sh.constrain(params["embed"], sh.tp, fsdp)
    logits = jnp.einsum("btd,vd->btv", h, emb)
    loss = causal_lm_loss(logits, tokens, sh)
    if cfg.moe:
        loss = loss + 0.01 * jnp.mean(auxs)
    return loss


# ---------------------------------------------------------------------------
# inference: prefill + decode
# ---------------------------------------------------------------------------
def prefill(cfg: LMConfig, sh: Shardings, params: Dict, tokens: jax.Array
            ) -> Tuple[jax.Array, Dict]:
    """[B, T] prompt -> (last-position logits [B, V], kv cache).

    Cache layout: k/v [L, B, T, KV, dh] (sequence-sharded for the long
    cells; see cache_specs)."""
    b, t = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    h = sh.constrain(h, sh.dp, None, None)
    cos, sin = rope_angles(jnp.arange(t), cfg.head_dim, cfg.rope_theta)

    def body(h, lw):
        h, _, k, v = _layer(cfg, sh, h, lw, cos, sin)
        # cache stash: keep the per-layer k/v sequence-sharded on the
        # model axis so the stacked scan output is never materialised
        # unsharded (matches cache_specs for the decode step)
        k = sh.constrain(k, sh.dp, sh.tp, None, None)
        v = sh.constrain(v, sh.dp, sh.tp, None, None)
        return h, (k, v)

    layer_fn = jax.checkpoint(body) if cfg.remat else body
    h, (ck, cv) = jax.lax.scan(layer_fn, h, params["layers"])
    h = rms_norm(h[:, -1:], params["final_norm"])
    logits = jnp.einsum("btd,vd->btv", h, params["embed"])[:, 0]
    return logits, {"k": ck, "v": cv, "len": jnp.full((), t, jnp.int32)}


def decode_step(cfg: LMConfig, sh: Shardings, params: Dict, cache: Dict,
                token: jax.Array) -> Tuple[jax.Array, Dict]:
    """One decode step: token [B] + cache -> (logits [B, V], cache).

    fori_loop over layers with dynamic weight slices keeps cache updates
    in place (dynamic_update_slice on the stacked [L, ...] cache)."""
    L = cfg.n_layers
    pos = cache["len"]
    b = token.shape[0]
    h = params["embed"][token[:, None]].astype(cfg.dtype)   # [B, 1, D]
    cos, sin = rope_angles(pos[None], cfg.head_dim, cfg.rope_theta)
    ck, cv = cache["k"], cache["v"]
    t_max = ck.shape[2]

    def body(l, carry):
        h, ck, cv = carry
        lw = jax.tree_util.tree_map(
            lambda w: jax.lax.dynamic_index_in_dim(w, l, 0, keepdims=False),
            params["layers"])
        xn = rms_norm(h, lw["attn_norm"])
        q = jnp.einsum("btd,dhk->bthk", xn, lw["wq"])
        k = jnp.einsum("btd,dhk->bthk", xn, lw["wk"])
        v = jnp.einsum("btd,dhk->bthk", xn, lw["wv"])
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        ckl = jax.lax.dynamic_slice_in_dim(ck, l, 1, axis=0)[0]
        cvl = jax.lax.dynamic_slice_in_dim(cv, l, 1, axis=0)[0]
        ckl = jax.lax.dynamic_update_slice(
            ckl, k.astype(ckl.dtype), (0, pos, 0, 0))
        cvl = jax.lax.dynamic_update_slice(
            cvl, v.astype(cvl.dtype), (0, pos, 0, 0))
        o = gqa_attention(q, ckl, cvl, causal=False, kv_len=pos + 1)
        attn = jnp.einsum("bthk,hkd->btd", o, lw["wo"])
        hh = h + attn
        hin = rms_norm(hh, lw["ffn_norm"])
        if cfg.moe:
            out, _ = _moe_ffn(cfg, sh, lw, hin)
        else:
            out = _dense_ffn(cfg, sh, lw, hin)
        hh = hh + out
        ck = jax.lax.dynamic_update_slice(ck, ckl[None], (l, 0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, cvl[None], (l, 0, 0, 0, 0))
        return hh, ck, cv

    h, ck, cv = jax.lax.fori_loop(0, L, body, (h, ck, cv))
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("btd,vd->btv", h, params["embed"])[:, 0]
    return logits, {"k": ck, "v": cv, "len": pos + 1}


def cache_specs(cfg: LMConfig, sh: Shardings, batch: int, t_max: int,
                *, shard_seq: bool) -> Dict:
    """ShapeDtypeStructs + PartitionSpecs for the decode cache."""
    kv, dh, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    shape = (L, batch, t_max, kv, dh)
    if shard_seq:
        # long-context: batch too small to cover the mesh; sequence is
        # sharded over every axis (flash-decoding-style combine)
        seq_axes = tuple(a for a in ("pod", "data", "model")
                         if sh.mesh is not None
                         and a in sh.mesh.axis_names)
        spec = sh.spec(None, None, seq_axes or None, None, None)
    else:
        # batch on (pod, data) + sequence on model: the 32k x 128-batch
        # caches are hundreds of GB and must shard on both
        spec = sh.spec(None, sh.dp, sh.tp, None, None)
    sds = jax.ShapeDtypeStruct(shape, cfg.dtype)
    return {
        "k": (sds, spec), "v": (sds, spec),
        "len": (jax.ShapeDtypeStruct((), jnp.int32), sh.spec()),
    }
