"""GNN architectures: graphcast, dimenet, graphsage, gat.

One unified representation drives all four shapes (DESIGN.md §4):
every batch is a (possibly block-diagonal) flat graph

    node_feat [N, df], edge_src [E], edge_dst [E], loss targets + mask

  * molecule          -> 128 small graphs as one disjoint union
  * full_graph_sm/lg  -> the graph itself
  * minibatch_lg      -> the sampled k-hop subgraph, loss on seed nodes

Message passing is gather -> compute -> segment_sum (JAX has no sparse
SpMM; the scatter/segment formulation IS the system, per the assignment
note).  dimenet adds triplet gathers (edge->edge angular messages);
gat adds segment-softmax edge attention.

Sharding: node and edge arrays are sharded over the *flattened* mesh
(every device owns a slice of edges); weights are replicated.  The
segment_sum over sharded edges lowers to partial sums + reduce-scatter
under SPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..compat import shard_map
from .common import Shardings


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                  # graphcast | dimenet | graphsage | gat
    n_layers: int
    d_hidden: int
    d_feat: int
    n_classes: int = 64
    n_heads: int = 8           # gat
    aggregator: str = "sum"
    d_edge: int = 4            # graphcast edge features
    n_radial: int = 6          # dimenet bases
    n_spherical: int = 7
    n_bilinear: int = 8
    n_out: int = 1
    dtype: Any = jnp.float32
    # sharded (shard_map) message passing: node/edge arrays stay sharded;
    # per-layer all_gather(h) + psum_scatter(agg) replaces the SPMD
    # full-replication gathers that blow HBM on ogb_products-scale cells
    sharded: bool = False

    def flat_axes(self, sh: Shardings):
        if sh.mesh is None:
            return None
        return tuple(sh.mesh.axis_names)


# ---------------------------------------------------------------------------
def _mlp_init(key, dims, dtype):
    ws = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        ws[f"w{i}"] = (jax.random.normal(k1, (a, b), jnp.float32)
                       * (a ** -0.5)).astype(dtype)
        ws[f"b{i}"] = jnp.zeros((b,), dtype)
    return ws


def _mlp(ws, x, act=jax.nn.relu, final_act=False):
    n = len([k for k in ws if k.startswith("w")])
    for i in range(n):
        x = x @ ws[f"w{i}"] + ws[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def _segment_sum(values, ids, n, sh: Shardings, flat):
    out = jax.ops.segment_sum(values, ids, num_segments=n)
    return sh.constrain(out, flat, None) if flat else out


def _segment_mean(values, ids, n, sh, flat):
    s = _segment_sum(values, ids, n, sh, flat)
    cnt = jax.ops.segment_sum(jnp.ones((values.shape[0], 1),
                                       values.dtype), ids, num_segments=n)
    return s / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# graphcast: encoder - interaction-network processor - decoder
# ---------------------------------------------------------------------------
def init_graphcast(cfg: GNNConfig, key) -> Dict:
    d = cfg.d_hidden
    keys = jax.random.split(key, 6)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(jax.random.fold_in(keys[0], i))
        layers.append({
            "edge_mlp": _mlp_init(k1, (3 * d, d, d), cfg.dtype),
            "node_mlp": _mlp_init(k2, (2 * d, d, d), cfg.dtype),
        })
    stacked = jax.tree_util.tree_map(lambda *x: jnp.stack(x), *layers)
    return {
        "enc_node": _mlp_init(keys[1], (cfg.d_feat, d, d), cfg.dtype),
        "enc_edge": _mlp_init(keys[2], (cfg.d_edge, d, d), cfg.dtype),
        "layers": stacked,
        "dec": _mlp_init(keys[3], (d, d, cfg.n_out), cfg.dtype),
    }


def forward_graphcast(cfg: GNNConfig, sh: Shardings, params: Dict,
                      batch: Dict) -> jax.Array:
    flat = cfg.flat_axes(sh)
    x, src, dst = batch["node_feat"], batch["edge_src"], batch["edge_dst"]
    n = x.shape[0]
    h = _mlp(params["enc_node"], x.astype(cfg.dtype))
    e = _mlp(params["enc_edge"], batch["edge_feat"].astype(cfg.dtype))
    h = sh.constrain(h, flat, None)
    e = sh.constrain(e, flat, None)

    def layer(carry, lw):
        h, e = carry
        msg_in = jnp.concatenate([e, h[src], h[dst]], axis=-1)
        e2 = e + _mlp(lw["edge_mlp"], msg_in)
        agg = _segment_sum(e2, dst, n, sh, flat)
        h2 = h + _mlp(lw["node_mlp"],
                      jnp.concatenate([h, agg], axis=-1))
        return (sh.constrain(h2, flat, None),
                sh.constrain(e2, flat, None)), None

    (h, e), _ = jax.lax.scan(jax.checkpoint(layer), (h, e),
                             params["layers"])
    pred = _mlp(params["dec"], h)                     # [N, n_out]
    mask = batch["loss_mask"].astype(jnp.float32)
    err = (pred.astype(jnp.float32)
           - batch["target"].astype(jnp.float32)) ** 2
    return jnp.sum(err.mean(-1) * mask) / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# dimenet: directional message passing with radial/spherical bases
# ---------------------------------------------------------------------------
def init_dimenet(cfg: GNNConfig, key) -> Dict:
    d = cfg.d_hidden
    ks = jax.random.split(key, 8)
    nsr = cfg.n_spherical * cfg.n_radial
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.fold_in(ks[0], i)
        k1, k2, k3, k4 = jax.random.split(kk, 4)
        layers.append({
            "msg_mlp": _mlp_init(k1, (d, d, d), cfg.dtype),
            "proj_kj": _mlp_init(k2, (d, d), cfg.dtype),
            "sbf_w": (jax.random.normal(k3, (nsr, cfg.n_bilinear),
                                        jnp.float32) * nsr ** -0.5
                      ).astype(cfg.dtype),
            "bilinear": (jax.random.normal(k4, (cfg.n_bilinear, d, d),
                                           jnp.float32) * d ** -0.5
                         ).astype(cfg.dtype),
        })
    stacked = jax.tree_util.tree_map(lambda *x: jnp.stack(x), *layers)
    return {
        "embed": _mlp_init(ks[1], (cfg.d_feat + cfg.n_radial, d, d),
                           cfg.dtype),
        "rbf_w": (jax.random.normal(ks[2], (cfg.n_radial, d), jnp.float32)
                  * cfg.n_radial ** -0.5).astype(cfg.dtype),
        "layers": stacked,
        "out": _mlp_init(ks[3], (d, d, cfg.n_out), cfg.dtype),
    }


def _rbf(dist, n_radial):
    """Bessel-style radial basis: sin(n pi d / c) / d."""
    d = jnp.maximum(dist, 1e-3)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    c = 5.0
    return jnp.sin(n * jnp.pi * d / c) / d


def _sbf(angle, n_spherical, n_radial):
    """cos(l * angle) x radial grid — simplified spherical basis."""
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    a = jnp.cos(angle[:, None] * l)               # [T, n_sph]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    r = jnp.sin(n * jnp.pi * 0.5)                 # fixed radial weight
    return (a[:, :, None] * r[None, None, :]).reshape(angle.shape[0], -1)


def forward_dimenet(cfg: GNNConfig, sh: Shardings, params: Dict,
                    batch: Dict) -> jax.Array:
    flat = cfg.flat_axes(sh)
    x, src, dst = batch["node_feat"], batch["edge_src"], batch["edge_dst"]
    dist = batch["edge_dist"]
    t_kj, t_ji, angle = (batch["tri_edge_kj"], batch["tri_edge_ji"],
                         batch["tri_angle"])
    n, e_cnt = x.shape[0], src.shape[0]
    rbf = _rbf(dist, cfg.n_radial).astype(cfg.dtype)       # [E, nr]
    sbf = _sbf(angle, cfg.n_spherical,
               cfg.n_radial).astype(cfg.dtype)             # [T, ns*nr]
    m = _mlp(params["embed"],
             jnp.concatenate([x.astype(cfg.dtype)[src], rbf], -1))
    m = sh.constrain(m, flat, None)
    rbf_g = rbf @ params["rbf_w"]                          # [E, d]

    def layer(m, lw):
        mk = _mlp(lw["proj_kj"], m)[t_kj]                  # [T, d]
        w = sbf @ lw["sbf_w"]                              # [T, nb]
        tri = jnp.einsum("tb,bdf,td->tf", w, lw["bilinear"], mk)
        agg = jax.ops.segment_sum(tri, t_ji, num_segments=e_cnt)
        m2 = m + _mlp(lw["msg_mlp"], m * rbf_g + agg)
        return sh.constrain(m2, flat, None), None

    m, _ = jax.lax.scan(jax.checkpoint(layer), m, params["layers"])
    node_e = _segment_sum(m, dst, n, sh, flat)
    pred = _mlp(params["out"], node_e)                     # [N, n_out]
    # graph-level energy: sum nodes per graph
    gid = batch["graph_id"]
    n_graphs = batch["target_g"].shape[0]
    energy = jax.ops.segment_sum(pred[:, 0], gid, num_segments=n_graphs)
    err = (energy.astype(jnp.float32)
           - batch["target_g"].astype(jnp.float32)) ** 2
    return jnp.mean(err)


# ---------------------------------------------------------------------------
# graphsage: concat(self, mean-neighbour) -> linear
# ---------------------------------------------------------------------------
def init_graphsage(cfg: GNNConfig, key) -> Dict:
    d = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        layers.append(_mlp_init(ks[i], (2 * d_in, d), cfg.dtype))
        d_in = d
    return {
        "layers": layers,   # ragged dims: keep as list
        "cls": _mlp_init(ks[-1], (d, cfg.n_classes), cfg.dtype),
    }


def forward_graphsage(cfg: GNNConfig, sh: Shardings, params: Dict,
                      batch: Dict) -> jax.Array:
    flat = cfg.flat_axes(sh)
    h = batch["node_feat"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = h.shape[0]
    for lw in params["layers"]:
        agg = _segment_mean(h[src], dst, n, sh, flat)
        h = jax.nn.relu(_mlp(lw, jnp.concatenate([h, agg], -1)))
        h = sh.constrain(h, flat, None)
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True),
                            1e-6)
    logits = _mlp(params["cls"], h)
    return _masked_ce(logits, batch["labels"], batch["loss_mask"])


# ---------------------------------------------------------------------------
# gat: segment-softmax edge attention
# ---------------------------------------------------------------------------
def init_gat(cfg: GNNConfig, key) -> Dict:
    h_, d = cfg.n_heads, cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(ks[i], 3)
        layers.append({
            "w": (jax.random.normal(k1, (d_in, h_, d), jnp.float32)
                  * d_in ** -0.5).astype(cfg.dtype),
            "a_src": (jax.random.normal(k2, (h_, d), jnp.float32)
                      * d ** -0.5).astype(cfg.dtype),
            "a_dst": (jax.random.normal(k3, (h_, d), jnp.float32)
                      * d ** -0.5).astype(cfg.dtype),
        })
        d_in = h_ * d
    return {"layers": layers,
            "cls": _mlp_init(ks[-1], (d_in, cfg.n_classes), cfg.dtype)}


def forward_gat(cfg: GNNConfig, sh: Shardings, params: Dict,
                batch: Dict) -> jax.Array:
    flat = cfg.flat_axes(sh)
    h = batch["node_feat"].astype(cfg.dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = h.shape[0]
    for li, lw in enumerate(params["layers"]):
        z = jnp.einsum("nd,dhf->nhf", h, lw["w"])          # [N, H, F]
        logit_s = jnp.einsum("nhf,hf->nh", z, lw["a_src"])
        logit_d = jnp.einsum("nhf,hf->nh", z, lw["a_dst"])
        e_logit = jax.nn.leaky_relu(logit_s[src] + logit_d[dst],
                                    negative_slope=0.2)    # [E, H]
        # segment softmax over incoming edges of dst
        e_max = jax.ops.segment_max(e_logit, dst, num_segments=n)
        e_exp = jnp.exp(e_logit - e_max[dst])
        e_den = jax.ops.segment_sum(e_exp, dst, num_segments=n)
        alpha = e_exp / jnp.maximum(e_den[dst], 1e-9)      # [E, H]
        msg = z[src] * alpha[..., None]
        h2 = jax.ops.segment_sum(msg, dst, num_segments=n)  # [N, H, F]
        h = jax.nn.elu(h2.reshape(n, -1))
        h = sh.constrain(h, flat, None)
    logits = _mlp(params["cls"], h)
    return _masked_ce(logits, batch["labels"], batch["loss_mask"])


# ---------------------------------------------------------------------------
def _masked_ce(logits, labels, mask):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = lse - gold
    m = mask.astype(jnp.float32)
    return jnp.sum(ce * m) / jnp.maximum(m.sum(), 1.0)


# ---------------------------------------------------------------------------
# shard_map message passing (production path for full-batch-large cells)
# ---------------------------------------------------------------------------
def forward_graphcast_sharded(cfg: GNNConfig, sh: Shardings, params: Dict,
                              batch: Dict) -> jax.Array:
    """Graphcast with owner-computes edge partitioning.

    Input contract (the BGP locality layout, DESIGN.md §5): each shard
    owns N/P nodes and their *incoming* edges; ``edge_dst`` is
    shard-local, ``edge_src`` is global.  Per layer the only collective
    is one tiled all_gather of the bf16 node state for the src halo;
    aggregation is a local segment_sum (no cross-shard scatter, whose
    bf16->f32-promoted transpose buffers dominated the 45 GB/device
    SPMD baseline; EXPERIMENTS.md §Perf G1).
    """
    axes = cfg.flat_axes(sh)
    mesh = sh.mesh
    from jax.sharding import PartitionSpec as P
    import functools as ft

    @ft.partial(shard_map, mesh=mesh,
                in_specs=(P(), {k: P(axes) if batch[k].ndim == 1
                                else P(axes, None) for k in batch}),
                out_specs=P())
    def run(params, b):
        x, src, dst = b["node_feat"], b["edge_src"], b["edge_dst"]
        n_local = x.shape[0]
        h = _mlp(params["enc_node"], x.astype(cfg.dtype))     # [N/P, d]
        e = _mlp(params["enc_edge"], b["edge_feat"].astype(cfg.dtype))

        e_local = batch["edge_src"].shape[0] // (
            mesh.size if mesh is not None else 1)
        n_chunks = 4 if e_local % 4 == 0 else 1

        def layer(carry, lw):
            h, e = carry
            h_full = jax.lax.all_gather(h, axes, axis=0, tiled=True)
            nl, d = h.shape
            # edge work in checkpointed chunks: only one chunk's message
            # tensors are live at a time (bounds the [E/P, 3d] buffers)
            src_c = src.reshape(n_chunks, -1)
            dst_c = dst.reshape(n_chunks, -1)
            e_c = e.reshape(n_chunks, -1, d)

            def chunk(agg, xs):
                s_, d_, e_ = xs
                msg = jnp.concatenate([e_, h_full[s_], h[d_]], -1)
                e2_ = e_ + _mlp(lw["edge_mlp"], msg)
                agg = agg + jax.ops.segment_sum(e2_, d_,
                                                num_segments=nl)
                return agg, e2_

            # (h * 0) keeps the carry varying over the manual mesh axes
            # (shard_map vma rule); a fresh zeros() would be unvarying
            agg, e2 = jax.lax.scan(jax.checkpoint(chunk),
                                   (h * 0).astype(e.dtype),
                                   (src_c, dst_c, e_c))
            e2 = e2.reshape(-1, d)
            h2 = h + _mlp(lw["node_mlp"],
                          jnp.concatenate([h, agg], axis=-1))
            return (h2, e2), None

        # block-wise activation checkpointing: the carry holds the big
        # [E/P, d] edge state, so per-layer stashing costs n_layers x
        # 1 GB on ogb_products — checkpoint every `blk` layers instead
        L = cfg.n_layers
        blk = 4 if L % 4 == 0 else 1
        stacked = jax.tree_util.tree_map(
            lambda w: w.reshape(L // blk, blk, *w.shape[1:]),
            params["layers"])

        def block(carry, lws):
            # inner layers are ALSO checkpointed: the block recompute
            # must not stash 4 layers of h_full/msg intermediates
            return jax.lax.scan(jax.checkpoint(layer), carry, lws)

        (h, e), _ = jax.lax.scan(jax.checkpoint(block), (h, e), stacked)
        pred = _mlp(params["dec"], h)
        mask = b["loss_mask"].astype(jnp.float32)
        err = (pred.astype(jnp.float32)
               - b["target"].astype(jnp.float32)) ** 2
        sse = jnp.sum(err.mean(-1) * mask)
        cnt = jnp.sum(mask)
        sse, cnt = jax.lax.psum((sse, cnt), axes)
        return sse / jnp.maximum(cnt, 1.0)

    return run(params, batch)


def forward_dimenet_sharded(cfg: GNNConfig, sh: Shardings, params: Dict,
                            batch: Dict) -> jax.Array:
    """DimeNet with partition-local triplets + owner-computes edges.

    Triplet indices reference edges *within the local shard* (angular
    neighbourhoods are partition-local under the BGP locality-aware
    edge ordering — DESIGN.md §Arch-applicability) and ``edge_dst`` is
    shard-local, so the directional message stack and the edge->node
    reduction are collective-free; only the src halo (one all_gather of
    the raw features) and the final energy psum cross shards.
    """
    axes = cfg.flat_axes(sh)
    mesh = sh.mesh
    from jax.sharding import PartitionSpec as P
    import functools as ft

    n_graphs = batch["target_g"].shape[0]

    @ft.partial(shard_map, mesh=mesh,
                in_specs=(P(), {k: (P(None) if k == "target_g"
                                    else P(axes) if batch[k].ndim == 1
                                    else P(axes, None)) for k in batch}),
                out_specs=P())
    def run(params, b):
        x, src, dst = b["node_feat"], b["edge_src"], b["edge_dst"]
        e_local = src.shape[0]
        rbf = _rbf(b["edge_dist"], cfg.n_radial).astype(cfg.dtype)
        sbf = _sbf(b["tri_angle"], cfg.n_spherical,
                   cfg.n_radial).astype(cfg.dtype)
        t_kj, t_ji = b["tri_edge_kj"], b["tri_edge_ji"]   # LOCAL ids
        x_full = jax.lax.all_gather(x.astype(cfg.dtype), axes, axis=0,
                                    tiled=True)
        m = _mlp(params["embed"],
                 jnp.concatenate([x_full[src], rbf], -1))  # [E/P, d]
        rbf_g = rbf @ params["rbf_w"]

        def layer(m, lw):
            mk = _mlp(lw["proj_kj"], m)[t_kj]             # local gather
            w = sbf @ lw["sbf_w"]
            tri = jnp.einsum("tb,bdf,td->tf", w, lw["bilinear"], mk)
            agg = jax.ops.segment_sum(tri, t_ji,
                                      num_segments=e_local)
            return m + _mlp(lw["msg_mlp"], m * rbf_g + agg), None

        m, _ = jax.lax.scan(jax.checkpoint(layer), m, params["layers"])
        node_e = jax.ops.segment_sum(m, dst,
                                     num_segments=x.shape[0])  # local dst
        pred = _mlp(params["out"], node_e)
        gid = b["graph_id"]
        energy = jax.lax.psum(
            jax.ops.segment_sum(pred[:, 0], gid, num_segments=n_graphs),
            axes)
        err = (energy.astype(jnp.float32)
               - b["target_g"].astype(jnp.float32)) ** 2
        return jnp.mean(err)

    return run(params, batch)


INIT = {"graphcast": init_graphcast, "dimenet": init_dimenet,
        "graphsage": init_graphsage, "gat": init_gat}
FORWARD = {"graphcast": forward_graphcast, "dimenet": forward_dimenet,
           "graphsage": forward_graphsage, "gat": forward_gat}
FORWARD_SHARDED = {"graphcast": forward_graphcast_sharded,
                   "dimenet": forward_dimenet_sharded}


def init_params(cfg: GNNConfig, key) -> Dict:
    return INIT[cfg.arch](cfg, key)


def forward_loss(cfg: GNNConfig, sh: Shardings, params: Dict,
                 batch: Dict) -> jax.Array:
    if (cfg.sharded and sh.mesh is not None
            and cfg.arch in FORWARD_SHARDED):
        return FORWARD_SHARDED[cfg.arch](cfg, sh, params, batch)
    return FORWARD[cfg.arch](cfg, sh, params, batch)
